"""The synchronous RL iteration loop (rollout → reward → experience →
train → weight update) with Seer driving the rollout phase.

This is the real-engine tier: every iteration generates actual tokens
with the current policy via :class:`~repro.core.rollout.SeerRollout`,
scores them with a programmatic task reward, builds a GRPO batch, takes
one (or more) AdamW steps, and pushes the new weights to the instances —
strictly on-policy, exactly the pipeline Seer preserves.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.request import Group, make_groups
from repro.core.rollout import SeerRollout
from repro.data.tasks import RewardWorker, Task
from repro.models import init_params
from repro.training.checkpoint import WeightUpdater, save
from repro.training.grpo import GRPOConfig, grpo_loss, pack_experience
from repro.training.optim import (OptConfig, OptState, adamw_update,
                                  init_opt_state)


@dataclass
class RLConfig:
    n_groups: int = 8
    group_size: int = 4
    max_new_tokens: int = 16
    temperature: float = 1.0
    iterations: int = 20
    train_steps_per_iter: int = 1
    seed: int = 0
    policy: str = "seer"
    spec_decode: bool = True
    n_instances: int = 2
    max_slots: int = 4
    cache_len: int = 256
    chunk_size: int = 64
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    log: Callable[[str], None] = print


@dataclass
class IterStats:
    iteration: int
    mean_reward: float
    loss: float
    rollout_seconds: float
    train_seconds: float
    weight_update_seconds: float
    tokens: int
    mean_acceptance: float
    metrics: dict = field(default_factory=dict)


def make_train_step(cfg: ModelConfig, gcfg: GRPOConfig, ocfg: OptConfig,
                    sctx=None):
    @jax.jit
    def step(params, opt_state: OptState, batch: dict):
        def loss_fn(p):
            return grpo_loss(cfg, p, batch, gcfg=gcfg, sctx=sctx)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, om = adamw_update(ocfg, params, grads, opt_state)
        metrics.update(om)
        return params, opt_state, loss, metrics

    return step


class RLTrainer:
    def __init__(self, cfg: ModelConfig, task: Task, rl: RLConfig,
                 gcfg: GRPOConfig = GRPOConfig(),
                 ocfg: Optional[OptConfig] = None, params=None):
        self.cfg = cfg
        self.task = task
        self.rl = rl
        self.gcfg = gcfg
        self.ocfg = ocfg or OptConfig(
            total_steps=rl.iterations * rl.train_steps_per_iter)
        key = jax.random.PRNGKey(rl.seed)
        self.params = params if params is not None \
            else init_params(cfg, key)[0]
        self.opt_state = init_opt_state(self.params)
        self.train_step = make_train_step(cfg, gcfg, self.ocfg)
        self.rollout = SeerRollout(
            cfg, self.params, n_instances=rl.n_instances,
            max_slots=rl.max_slots, cache_len=rl.cache_len,
            chunk_size=rl.chunk_size, policy=rl.policy,
            spec_decode=rl.spec_decode, base_seed=rl.seed)
        self.updater = WeightUpdater(self.rollout.instances)
        self.rewards = RewardWorker(task)
        self.history: List[IterStats] = []

    def _sample_groups(self, it: int) -> List[Group]:
        rng = np.random.default_rng(self.rl.seed * 7919 + it)
        prompts = [self.task.sample_prompt(rng)
                   for _ in range(self.rl.n_groups)]
        return make_groups(
            prompts, self.rl.group_size,
            max_new_tokens=self.rl.max_new_tokens,
            temperature=self.rl.temperature,
            stop_token=None, seed=self.rl.seed * 131 + it,
            prefix=f"it{it}-g")

    def run(self) -> List[IterStats]:
        rl = self.rl
        for it in range(rl.iterations):
            # ---- rollout (Seer) --------------------------------------------
            t0 = time.monotonic()
            groups = self._sample_groups(it)
            # fresh context/DGDS per iteration (the paper drops group state
            # at iteration end; CSTs are iteration-scoped)
            self.rollout.ctx = type(self.rollout.ctx)(
                max_gen_length=rl.cache_len)
            res = self.rollout.run(groups)
            t_roll = time.monotonic() - t0

            # ---- rewards (async backend drained here) ----------------------
            prompts, responses, logprobs = {}, {}, {}
            for g in groups:
                for r in g.requests:
                    prompts[r.req_id] = r.prompt
                    responses[r.req_id] = r.generated
                    logprobs[r.req_id] = r.logprobs
                    self.rewards.submit(r.req_id, r.prompt, r.generated)
            rewards = self.rewards.collect()

            # ---- experience + training -------------------------------------
            t1 = time.monotonic()
            max_len = max(len(p) for p in prompts.values()) \
                + rl.max_new_tokens
            batch = pack_experience(
                self.cfg, responses, prompts, rewards, logprobs,
                rl.group_size, max_len, gcfg=self.gcfg)
            loss = jnp.zeros(())
            metrics: dict = {}
            for _ in range(rl.train_steps_per_iter):
                self.params, self.opt_state, loss, metrics = \
                    self.train_step(self.params, self.opt_state, batch)
            loss.block_until_ready()
            t_train = time.monotonic() - t1

            # ---- weight update ----------------------------------------------
            t2 = time.monotonic()
            self.updater.push(self.params)
            t_upd = time.monotonic() - t2

            mean_r = float(np.mean(list(rewards.values())))
            st = IterStats(
                iteration=it, mean_reward=mean_r, loss=float(loss),
                rollout_seconds=t_roll, train_seconds=t_train,
                weight_update_seconds=t_upd, tokens=res.stats.tokens,
                mean_acceptance=res.stats.mean_acceptance,
                metrics={k: float(v) for k, v in metrics.items()})
            self.history.append(st)
            rl.log(f"[iter {it:3d}] reward={mean_r:.3f} loss={float(loss):+.4f} "
                   f"rollout={t_roll:.1f}s train={t_train:.1f}s "
                   f"acc={res.stats.mean_acceptance:.2f}")
            if rl.checkpoint_dir and rl.checkpoint_every and \
                    (it + 1) % rl.checkpoint_every == 0:
                save(f"{rl.checkpoint_dir}/it{it + 1}", self.params, it + 1)
        return self.history

"""Position-keyed sampling.

The RNG for the token at absolute position p of request r depends only on
(base_key, r_seed, p).  Consequently a speculative-verify forward and a
plain sequential decode sample *identical* tokens given identical prefixes —
speculative decoding is bitwise lossless even at temperature > 0, which is
the on-policy guarantee Seer's synchronous RL setting requires (§3.4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def position_keys(base_key: jax.Array, seeds: jax.Array,
                  positions: jax.Array) -> jax.Array:
    """seeds: (B,), positions: (B,T) -> uint32 keys (B,T,2)."""
    def one(seed, pos_row):
        k = jax.random.fold_in(base_key, seed)
        return jax.vmap(lambda p: jax.random.key_data(
            jax.random.fold_in(k, p)))(pos_row)
    return jax.vmap(one)(seeds, positions)


def sample_tokens(logits: jax.Array, keys: jax.Array,
                  temps: jax.Array,
                  row_valid: jax.Array = None) -> jax.Array:
    """logits (B,T,V) f32; keys (B,T,2) uint32; temps (B,).

    temp <= 0 -> greedy; else Gumbel-max sampling (exact categorical).

    ``row_valid`` (B,) bool marks rows whose samples are consumed.  In a
    mixed prefill/decode step, prefill rows carry chunk tokens whose
    "samples" are never used; they are forced greedy (no Gumbel draw from
    garbage keys) and returned as -1 so a stray consumer fails loudly.
    """
    B, T, V = logits.shape
    lf = logits.astype(jnp.float32)
    if row_valid is not None:
        temps = jnp.where(row_valid, temps, 0.0)

    def one(lrow, krow, temp):
        def pos(l, kd):
            key = jax.random.wrap_key_data(kd)
            g = jax.random.gumbel(key, (V,), jnp.float32)
            scaled = jnp.where(temp > 0, l / jnp.maximum(temp, 1e-6) + g, l)
            return jnp.argmax(scaled).astype(jnp.int32)
        return jax.vmap(pos)(lrow, krow)

    sampled = jax.vmap(one)(lf, keys, temps)
    if row_valid is not None:
        sampled = jnp.where(row_valid[:, None], sampled, -1)
    return sampled


def draft_acceptance(sampled: jax.Array, tokens: jax.Array,
                     anchor: jax.Array, n_drafts: jax.Array) -> jax.Array:
    """Longest accepted draft prefix per row, computed on device.

    Row layout: column ``anchor[i]`` of ``tokens`` holds the row's
    pending token and columns ``anchor+1 .. anchor+n_drafts`` its draft
    tokens.  ``sampled[i, anchor+j]`` is the token the model samples
    after consuming draft ``j-1`` (the pending token for ``j=0``), so
    draft ``j`` is accepted iff it equals that sample and every earlier
    draft was accepted — the same longest-prefix match the host-side
    reference path performs, but without a device sync.

    sampled/tokens: (B, T); anchor/n_drafts: (B,) int32 -> (B,) int32.
    """
    B, T = tokens.shape
    if T == 1:
        return jnp.zeros((B,), jnp.int32)
    j = jnp.arange(T - 1)
    d_cols = jnp.clip(anchor[:, None] + 1 + j[None, :], 0, T - 1)
    c_cols = jnp.clip(anchor[:, None] + j[None, :], 0, T - 1)
    d_tok = jnp.take_along_axis(tokens, d_cols, axis=1)
    chain = jnp.take_along_axis(sampled, c_cols, axis=1)
    ok = (d_tok == chain) & (j[None, :] < n_drafts[:, None])
    return jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1),
                   axis=1).astype(jnp.int32)


def tree_acceptance(sampled: jax.Array, tokens: jax.Array,
                    parent: jax.Array, depth: jax.Array,
                    within: jax.Array, mask: jax.Array,
                    anchor: jax.Array) -> tuple:
    """Longest accepted *path* through a draft token tree, on device.

    Row layout (see ``TokenTree``): tree nodes occupy columns after the
    row's anchor; ``parent[b,c]`` is the column of node c's parent (the
    anchor's column for depth-1 nodes, -1 for non-node columns),
    ``depth[b,c]`` its depth from the anchor (0 = anchor / non-tree
    column), and ``within[b,c,c']`` the ancestor-or-self mask the
    attention step used.  A node is accepted iff its token equals the
    token the model sampled at its parent AND every ancestor is
    accepted — evaluated in closed form as "all ancestors' edges
    match", vectorised through the ancestor mask (no sequential scan).
    Children of one node carry distinct tokens (the tree builder
    dedups), so accepted nodes always form a single chain and the
    deepest accepted node identifies the winning path.

    Returns ``(n_accepted (B,), path_col (B,T), accepted (B,T))``:
    ``path_col[b,d]`` is the column of the accepted-path node at depth d
    (the anchor for d = 0 or d > n_accepted) — the gather indices that
    relayout the sampled/logprob chain path-major for the host — and
    ``accepted`` the per-node accept flags (the SSM replay mask).
    """
    B, T = tokens.shape
    node = (depth > 0) & mask
    par = jnp.clip(parent, 0, T - 1)
    edge_ok = jnp.where(
        parent >= 0,
        tokens == jnp.take_along_axis(sampled, par, axis=1), True)
    # accepted iff every within-visible column's edge holds (non-node
    # columns have parent -1 => edge_ok True, so the anchor and padding
    # never veto)
    acc = node & jnp.all(edge_ok[:, None, :] | ~within, axis=2)
    n_acc = jnp.max(jnp.where(acc, depth, 0), axis=1).astype(jnp.int32)
    d = jnp.arange(T, dtype=jnp.int32)[None, :]
    hit = acc[:, None, :] & (depth[:, None, :] == d[:, :, None]) \
        & (d[:, :, None] > 0)                                # (B,Td,Tc)
    has = jnp.any(hit, axis=2)
    path_col = jnp.where(has, jnp.argmax(hit, axis=2),
                         anchor[:, None]).astype(jnp.int32)
    return n_acc, path_col, acc


def token_logprobs_at(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """logprob of ``tokens`` under softmax(logits); (B,T,V),(B,T)->(B,T) f32."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    sel = jnp.take_along_axis(lf, tokens[..., None], axis=-1)[..., 0]
    return sel - logz

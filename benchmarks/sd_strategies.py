"""Fig. 11: throughput and mean acceptance length of SD strategies.

All strategies run on the same divided+context scheduling substrate so the
comparison isolates the decoding mechanism, mirroring the paper's ablation
(single rollout iteration).  Strategies: none, SuffixDecoding (per-request
CST, γ=16), Seer grouped CST (adaptive MBA, γ_max=8), grouped+multipath
(k=4), grouped+tree (multi-path drafts verified as one token tree —
equal draft budget, branch rescues), dedicated 7B draft model (γ=3),
MTP (γ=1).  Paper: grouped SD wins throughput everywhere (up to 1.3×
over the best vanilla SD); grouped CST beats per-request CST acceptance
by ~+0.22; the draft model has the best acceptance but the worst
throughput (draft overhead).

The real-engine tree-verification micro-benchmark
(``bench_engine_tree``) also runs here so BENCH_rollout.json carries
its ``engine_tree`` section next to the simulated strategy sweep.
"""
from __future__ import annotations

from benchmarks.common import (ensure_engine_tree_record, run_sim,
                               save_result, table, workload)

STRATEGIES = [
    ("No SD", "none"),
    ("Suffix (per-req CST)", "suffix"),
    ("Draft model 7B", "draft_model"),
    ("MTP", "mtp"),
    ("Grouped (Seer)", "grouped"),
    ("Grouped+multipath", "grouped+multipath"),
    ("Grouped+tree", "grouped+tree"),
]


def run(workloads=("moonlight", "qwen2-vl-72b", "kimi-k2"), seed=0):
    rows, record = [], {}
    for w in workloads:
        wl = workload(w, seed=seed)
        res = {}
        for label, sd in STRATEGIES:
            res[label] = run_sim(w, wl, mode="divided", policy="seer",
                                 sd=sd)
        base = res["No SD"].tokens_per_sec
        for label, _ in STRATEGIES:
            r = res[label]
            rows.append({
                "workload": w, "strategy": label,
                "norm_thpt": r.tokens_per_sec / base,
                "acc_len": r.mean_acceptance_len,
            })
        best_vanilla = max(res[k].tokens_per_sec for k in
                           ("Suffix (per-req CST)", "Draft model 7B", "MTP"))
        record[w] = {
            "grouped_over_no_sd":
                res["Grouped (Seer)"].tokens_per_sec / base,
            "grouped_over_best_vanilla":
                res["Grouped (Seer)"].tokens_per_sec / best_vanilla,
            "acc_gain_grouped_vs_suffix":
                res["Grouped (Seer)"].mean_acceptance_len
                - res["Suffix (per-req CST)"].mean_acceptance_len,
            "paper_acc_gain": 0.22,
            "paper_max_speedup_over_vanilla": 1.3,
            "tree_over_multipath":
                res["Grouped+tree"].tokens_per_sec
                / res["Grouped+multipath"].tokens_per_sec,
        }
    txt = table(rows, ["workload", "strategy", "norm_thpt", "acc_len"],
                "Fig. 11 — SD strategies (throughput + acceptance)")
    save_result("sd_strategies", {"rows": rows, "record": record,
                                  "table": txt})
    try:
        ensure_engine_tree_record()
    except Exception as e:  # noqa: BLE001 - report-and-continue CLI
        print(f"[sd_strategies] engine tree bench failed: {e}",
              flush=True)
    return record


if __name__ == "__main__":
    run()

from repro.engine.engine import (BlobCorruptionError, EngineSeq, Instance,
                                 KVBlob, StepFunctions, StepTicket,
                                 donation_supported)
from repro.engine.sampling import (draft_acceptance, position_keys,
                                   sample_tokens, token_logprobs_at,
                                   tree_acceptance)
from repro.engine.token_tree import (TokenTree, build_token_tree,
                                     chain_tree)

__all__ = ["BlobCorruptionError",
           "EngineSeq", "Instance", "KVBlob", "StepFunctions", "StepTicket",
           "donation_supported", "draft_acceptance", "position_keys",
           "sample_tokens", "token_logprobs_at", "tree_acceptance",
           "TokenTree", "build_token_tree", "chain_tree"]

"""Flash attention Pallas TPU kernel (prefill / training hot path).

Canonical TPU tiling: grid = (B*Hq, nq, nk) with the kv axis innermost so
the online-softmax accumulators (m, l, acc) live in VMEM scratch across kv
iterations and the output tile is written once on the last kv step.

Block shapes are MXU-aligned (128 multiples on the q/kv token dims; head
dim D is the lane dim).  GQA is handled in the BlockSpec index maps: query
head h reads kv head h // (Hq // Hk) — no repeated KV materialisation in
HBM (the `jnp.repeat` the reference does is exactly the memory traffic
this kernel removes).

Causal + sliding-window masking is applied from absolute positions
(q_offset + global row, global col); `pl.when` skips fully-masked blocks'
FLOPs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, q_offset: int,
                  block_q: int, block_k: int, n_k: int, tk_valid: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + qi * block_q + jax.lax.iota(jnp.int32, block_q)
    k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)

    # block-level skip: entirely above the diagonal / outside the window
    last_q = q_offset + qi * block_q + block_q - 1
    first_q = q_offset + qi * block_q
    first_k = ki * block_k
    last_k = first_k + block_k - 1
    run = jnp.asarray(True)
    if causal:
        run = jnp.logical_and(run, first_k <= last_q)
    if window:
        run = jnp.logical_and(run, last_k > first_q - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale            # (bq, D)
        k = k_ref[0].astype(jnp.float32)                    # (bk, D)
        v = v_ref[0].astype(jnp.float32)                    # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (bq, bk)
        mask = k_pos[None, :] < tk_valid
        if causal:
            mask = jnp.logical_and(mask, k_pos[None, :] <= q_pos[:, None])
        if window:
            mask = jnp.logical_and(mask,
                                   k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                                 # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = corr * l_scr[...] + p.sum(-1, keepdims=True)
        acc_scr[...] = corr * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                     # fully-masked rows
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, q_offset: int = 0,
                           causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q: (B,Tq,Hq,D); k,v: (B,Tk,Hk,D) -> (B,Tq,Hq,D)."""
    B, Tq, Hq, D = q.shape
    Tk, Hk = k.shape[1], k.shape[2]
    assert Hq % Hk == 0, (Hq, Hk)
    rep = Hq // Hk
    block_q = min(block_q, Tq) if Tq >= 8 else Tq
    block_k = min(block_k, Tk)
    pq = (-Tq) % block_q
    pk = (-Tk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    Tqp, Tkp = Tq + pq, Tk + pk
    n_q, n_k = Tqp // block_q, Tkp // block_k

    qf = qp.transpose(0, 2, 1, 3).reshape(B * Hq, Tqp, D)
    kf = kp.transpose(0, 2, 1, 3).reshape(B * Hk, Tkp, D)
    vf = vp.transpose(0, 2, 1, 3).reshape(B * Hk, Tkp, D)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b = bh // Hq
        h = bh % Hq
        return (b * Hk + h // rep, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=D ** -0.5, causal=causal, window=window,
        q_offset=q_offset, block_q=block_q, block_k=block_k, n_k=n_k,
        tk_valid=Tk)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Tqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(B, Hq, Tqp, D).transpose(0, 2, 1, 3)
    return out[:, :Tq]

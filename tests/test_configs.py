"""Config registry: exact assigned numbers + tiny-variant constraints."""
import pytest

from repro.configs import (INPUT_SHAPES, get_config, get_tiny_config,
                           list_archs)

ASSIGNED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
    "yi-6b": (32, 4096, 32, 4, 11008, 64000),
    "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
    "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
    "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
}


def test_all_archs_registered():
    assert sorted(list_archs()) == sorted(ASSIGNED)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_published_numbers(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert (cfg.d_ff or 0) == ff or cfg.moe_d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.source, f"{arch} missing source citation"


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_tiny_variant_bounds(arch):
    t = get_tiny_config(arch)
    assert t.num_layers <= 2 or (t.arch_type in ("hybrid", "vlm")
                                 and t.num_layers <= 4)
    assert t.d_model <= 512
    assert t.num_experts <= 4
    assert t.arch_type == get_config(arch).arch_type


def test_moe_extras():
    ds = get_config("deepseek-moe-16b")
    assert ds.num_experts == 64 and ds.moe_top_k == 6
    assert ds.num_shared_experts == 2
    mx = get_config("mixtral-8x7b")
    assert mx.num_experts == 8 and mx.moe_top_k == 2
    assert mx.sliding_window == 4096
    ms = get_config("moonshot-v1-16b-a3b")
    assert ms.num_experts == 64 and ms.moe_top_k == 6


def test_ssm_extras():
    mb = get_config("mamba2-370m")
    assert mb.ssm_state == 128 and mb.arch_type == "ssm"
    zb = get_config("zamba2-1.2b")
    assert zb.ssm_state == 64 and zb.arch_type == "hybrid"


def test_input_shapes():
    s = INPUT_SHAPES
    assert s["train_4k"].seq_len == 4096 and s["train_4k"].global_batch == 256
    assert s["prefill_32k"].seq_len == 32768
    assert s["prefill_32k"].global_batch == 32
    assert s["decode_32k"].global_batch == 128
    assert s["long_500k"].seq_len == 524288
    assert s["long_500k"].global_batch == 1


def test_param_counts_plausible():
    # sanity: analytic counts land in the right ballpark
    assert 5.5e9 < get_config("yi-6b").num_params() < 7e9
    assert 40e9 < get_config("mixtral-8x7b").num_params() < 50e9
    assert 3e8 < get_config("mamba2-370m").num_params() < 5e8
    mx = get_config("mixtral-8x7b")
    assert mx.active_params() < 0.35 * mx.num_params()

from repro.data.workload import (KIMI_K2, MOONLIGHT, QWEN2_VL_72B,
                                 WORKLOADS, Workload, WorkloadSpec,
                                 group_token_streams, length_stats,
                                 make_workload, sample_lengths)

__all__ = [
    "KIMI_K2", "MOONLIGHT", "QWEN2_VL_72B", "WORKLOADS", "Workload",
    "WorkloadSpec", "group_token_streams", "length_stats", "make_workload",
    "sample_lengths",
]
from repro.data.tasks import RewardWorker, Task, Tokenizer, make_task  # noqa: E402

__all__ += ["RewardWorker", "Task", "Tokenizer", "make_task"]

"""Logical-axis based sharding rules.

Params carry logical axis names (see models/common.Builder).  A RuleSet maps
logical names to mesh axes with divisibility guards: if a dim does not divide
the mesh axis size it is replicated (e.g. whisper's 6 heads or yi's 4 kv
heads on a 16-way model axis).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical axis vocabulary used by model init:
#   layers        stacked-layer axis (never sharded)
#   embed         d_model rows (FSDP target in train mode)
#   heads, kv     attention head dims (merged H*hd)
#   ff            MLP hidden
#   vocab         embedding rows / logits
#   expert        MoE expert axis
#   eff           per-expert hidden
#   state, conv, ssm_in   mamba dims (replicated)
#   batch, seq, cache_seq activation/cache axes


@dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    dp: tuple = ("data",)          # mesh axes carrying the batch dim
    tp: str = "model"              # tensor/expert-parallel mesh axis
    fsdp: Optional[str] = None     # mesh axis for param FSDP (train mode)
    seq_shard: bool = True         # Megatron-style residual seq sharding

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp]

    def dp_size(self) -> int:
        s = 1
        for a in self.dp:
            s *= self.mesh.shape[a]
        return s


def _mesh_axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def logical_to_spec(axes: tuple, rules: dict, mesh: Mesh,
                    shape: tuple) -> P:
    """Map one leaf's logical axes to a PartitionSpec with guards."""
    out = []
    used = set()
    for dim, name in zip(shape, axes):
        mesh_axes = rules.get(name)
        if mesh_axes is None:
            out.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        # drop axes already used by another dim of this leaf
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        size = _mesh_axis_size(mesh, mesh_axes)
        if mesh_axes and size > 0 and dim % size == 0:
            out.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
            used.update(mesh_axes)
        else:
            out.append(None)
    return P(*out)


def param_rules(sctx: ShardCtx, train: bool) -> dict:
    tp = sctx.tp
    rules = {
        "heads": tp, "kv": tp, "ff": tp, "vocab": tp,
        # expert-parallel when E divides the axis; logical_to_spec's
        # used-axis bookkeeping makes "eff" the tensor-parallel fallback
        # (e.g. Mixtral's 8 experts on a 16-way axis shard d_ff instead)
        "expert": tp, "eff": tp,
        "embed": None, "state": None, "conv": None, "ssm_in": None,
        "layers": None, "norm": None,
    }
    if train and sctx.fsdp:
        rules["embed"] = sctx.fsdp
    return rules


def param_sharding(params_axes, sctx: ShardCtx, train: bool,
                   params_shapes) -> dict:
    """Tree of NamedShardings matching the params tree."""
    rules = param_rules(sctx, train)

    def one(axes, shape):
        spec = logical_to_spec(axes, rules, sctx.mesh, shape)
        return NamedSharding(sctx.mesh, spec)

    return jax.tree.map(
        one, params_axes, params_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def shape_tree(params) -> dict:
    return jax.tree.map(lambda x: tuple(x.shape), params)


# -------- activation constraint helpers ------------------------------------

def constrain(x, sctx: Optional[ShardCtx], *spec_axes):
    if sctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(sctx.mesh, P(*spec_axes)))


def batch_axes(sctx: Optional[ShardCtx], batch_size: int):
    """Mesh axes for the batch dim, guarded on divisibility."""
    if sctx is None:
        return None
    axes = tuple(a for a in sctx.dp)
    size = _mesh_axis_size(sctx.mesh, axes)
    if size and batch_size % size == 0:
        return axes
    # try progressively smaller prefixes
    for k in range(len(axes) - 1, 0, -1):
        sub = axes[:k]
        if batch_size % _mesh_axis_size(sctx.mesh, sub) == 0:
            return sub
    return None


def seq_axis(sctx: Optional[ShardCtx], seq_len: int):
    if sctx is None or not sctx.seq_shard:
        return None
    if seq_len % sctx.tp_size == 0:
        return sctx.tp
    return None
